"""Secure Scalar Product Protocol (paper Appendix D, Algorithm 2)."""
import numpy as np
import pytest

from repro.core.sspp import secure_dot, secure_similarity_matrix


def test_exactness(rng):
    for _ in range(20):
        a = rng.normal(size=16)
        b = rng.normal(size=16)
        got = secure_dot(a, b, seed=int(rng.integers(1 << 30)))
        assert got == pytest.approx(float(a @ b), rel=1e-9, abs=1e-9)


def test_similarity_matrix_symmetric_exact(rng):
    feats = rng.normal(size=(7, 5))
    v = secure_similarity_matrix(feats, seed=1)
    assert np.allclose(v, v.T)
    assert np.allclose(v, feats @ feats.T, atol=1e-8)


def test_server_transcript_masks_features(rng):
    """Everything the server sees is masked: the uploaded vectors differ from
    the raw features by the (unknown-to-an-outside-observer) random masks, and
    the blinded partials don't expose the dot product components."""
    a = rng.normal(size=32)
    b = rng.normal(size=32)
    transcript = []
    dot = secure_dot(a, b, seed=9, transcript=transcript)
    a_hat, b_hat, u, v1, v2 = transcript
    assert not np.allclose(a_hat, a, atol=1e-3)
    assert not np.allclose(b_hat, b, atol=1e-3)
    # the final product only emerges from the v1 + v2 combination
    assert v1 + v2 == pytest.approx(dot)
    assert abs(v1 - dot) > 1e-6 and abs(v2 - dot) > 1e-6


def test_transcript_varies_with_seed_while_dot_constant(rng):
    """Reconstruction-infeasibility property: the same (A, B) pair produces
    completely different server-visible transcripts under different protocol
    randomness, while the output stays fixed — the transcript therefore
    cannot determine A or B."""
    a = rng.normal(size=8)
    b = rng.normal(size=8)
    t1, t2 = [], []
    d1 = secure_dot(a, b, seed=1, transcript=t1)
    d2 = secure_dot(a, b, seed=2, transcript=t2)
    assert d1 == pytest.approx(d2)
    assert not np.allclose(t1[0], t2[0])
    assert not np.allclose(t1[1], t2[1])
    assert t1[2] != pytest.approx(t2[2])

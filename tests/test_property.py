"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")       # optional dev dependency
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import graph as G
from repro.core.fairness import count_variance, gini
from repro.core.sampler import _fedgs_solve


@st.composite
def sym_matrix(draw, nmin=3, nmax=12):
    n = draw(st.integers(nmin, nmax))
    vals = draw(st.lists(st.floats(0, 10, allow_nan=False), min_size=n * n,
                         max_size=n * n))
    q = np.array(vals).reshape(n, n)
    q = 0.5 * (q + q.T)
    np.fill_diagonal(q, 0)
    return q


@settings(max_examples=25, deadline=None)
@given(sym_matrix(), st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
def test_solver_invariants(q, m, seed):
    """|S| = min(m, |A|) exactly, S subset of A, deterministic."""
    n = q.shape[0]
    rng = np.random.default_rng(seed)
    avail = rng.random(n) < 0.7
    if not avail.any():
        avail[0] = True
    m_eff = min(m, int(avail.sum()))
    s1 = np.asarray(_fedgs_solve(jnp.asarray(q, jnp.float32),
                                 jnp.asarray(avail), m=m_eff, max_sweeps=8))
    s2 = np.asarray(_fedgs_solve(jnp.asarray(q, jnp.float32),
                                 jnp.asarray(avail), m=m_eff, max_sweeps=8))
    assert np.array_equal(s1, s2)                     # deterministic
    sel = np.flatnonzero(s1)
    assert len(sel) == m_eff
    assert np.all(avail[sel])


@settings(max_examples=15, deadline=None)
@given(sym_matrix(3, 10), st.integers(1, 5), st.integers(0, 2 ** 31 - 1))
def test_sweep_monotonicity(q, m, seed):
    """The best-swap local search never decreases the Eq. 16 objective:
    s^T Q s is non-decreasing in ``max_sweeps`` (every applied swap must
    improve by > 1e-9; a no-swap sweep leaves s unchanged).  Tolerance
    covers the float32 drift between the incrementally-maintained row sums
    and the exact objective."""
    n = q.shape[0]
    rng = np.random.default_rng(seed)
    q = q - np.diag(rng.normal(size=n))        # counts-penalty diagonal
    avail = rng.random(n) < 0.7
    if not avail.any():
        avail[0] = True
    m_eff = min(m, int(avail.sum()))
    qj = jnp.asarray(q, jnp.float32)
    q64 = np.asarray(qj, np.float64)

    def objective(sweeps):
        s = np.asarray(_fedgs_solve(qj, jnp.asarray(avail), m=m_eff,
                                    max_sweeps=sweeps)).astype(np.float64)
        return s @ q64 @ s

    objs = [objective(k) for k in (0, 1, 2, 4, 8)]
    for lo, hi in zip(objs, objs[1:]):
        assert hi >= lo - 1e-3 * (1.0 + abs(lo)), objs


@settings(max_examples=25, deadline=None)
@given(sym_matrix(3, 10))
def test_fw_fixpoint_and_triangle(r):
    """FW is idempotent and satisfies the triangle inequality."""
    h = G.shortest_paths(r)
    h2 = G.shortest_paths(h)
    assert np.allclose(h, h2, equal_nan=True)
    n = len(h)
    # 1e-5 slack: the shared pipeline runs in float32 (DESIGN.md §9)
    for k in range(n):
        assert np.all(h <= h[:, k:k + 1] + h[k:k + 1, :] + 1e-5)
    # distances never exceed direct edges
    assert np.all(h <= r + 1e-5)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 1000), min_size=2, max_size=50))
def test_fairness_metrics_bounds(counts):
    v = np.array(counts, float)
    assert count_variance(v) >= 0
    gi = gini(v)
    assert -1e-9 <= gi <= 1.0
    # perfectly uniform counts => zero variance, zero gini
    u = np.full(len(v), 7.0)
    assert count_variance(u) == 0.0
    assert abs(gini(u)) < 1e-9


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30), st.floats(0.05, 0.95), st.integers(0, 10 ** 6))
def test_availability_probs_always_valid(n, beta, seed):
    from repro.core.availability import LogNormal, SinLogNormal
    for cls in (LogNormal, SinLogNormal):
        mode = cls(n, beta=beta, seed=seed)
        for t in (0, 7, 100):
            p = mode.probs(t)
            assert p.shape == (n,)
            assert np.all(p >= 0) and np.all(p <= 1)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(0.1, 100, allow_nan=False), min_size=2, max_size=8),
       st.integers(0, 2 ** 31 - 1))
def test_aggregation_is_convex_combination(weights, seed):
    """Aggregating identical client params returns them unchanged; aggregated
    values always lie inside the per-client min/max envelope (Eq. 18 is a
    convex combination)."""
    import jax.numpy as jnp
    from repro.fed.server import aggregate
    rng = np.random.default_rng(seed)
    m = len(weights)
    stacked = {"w": jnp.asarray(rng.normal(size=(m, 4)), jnp.float32)}
    out = np.asarray(aggregate(stacked, jnp.asarray(weights, jnp.float32))["w"])
    lo = np.asarray(stacked["w"]).min(0) - 1e-5
    hi = np.asarray(stacked["w"]).max(0) + 1e-5
    assert np.all(out >= lo) and np.all(out <= hi)
    same = {"w": jnp.broadcast_to(stacked["w"][0], stacked["w"].shape)}
    out2 = np.asarray(aggregate(same, jnp.asarray(weights, jnp.float32))["w"])
    np.testing.assert_allclose(out2, np.asarray(stacked["w"][0]), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 40), st.integers(1, 60))
def test_secure_dot_exact_property(n, seed):
    from repro.core.sspp import secure_dot
    rng = np.random.default_rng(seed)
    a = rng.normal(size=n)
    b = rng.normal(size=n)
    assert abs(secure_dot(a, b, seed=seed) - a @ b) < 1e-8


# --------------------------------------------------- checkpoint round-trip
@st.composite
def _ckpt_leaf(draw):
    """One checkpoint leaf: any dtype the engines carry (incl. bfloat16 and
    bool masks), any rank 0-2 shape incl. 0-sized dims and 0-d scalars."""
    dtype = draw(st.sampled_from(
        ["float32", "float64", "int32", "int64", "bool", "bfloat16"]))
    shape = tuple(draw(st.lists(st.integers(0, 3), min_size=0, max_size=2)))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31 - 1)))
    vals = rng.normal(size=shape)
    if dtype == "bool":
        return vals > 0
    if dtype in ("int32", "int64"):
        return (vals * 10).astype(dtype)
    if dtype == "bfloat16":
        import ml_dtypes
        return vals.astype(ml_dtypes.bfloat16)
    return vals.astype(dtype)


_ckpt_keys = st.sampled_from(
    ["prev", "m1", "m2", "mem", "tau", "w", "b", "state", "a"])
# nested dict/list/tuple pytrees, INCLUDING empty containers — exactly the
# structures the scan carry holds (a stateless sampler's state is {})
_ckpt_tree = st.dictionaries(
    _ckpt_keys,
    st.recursive(
        _ckpt_leaf(),
        lambda kids: st.one_of(
            st.dictionaries(_ckpt_keys, kids, max_size=3),
            st.lists(kids, max_size=3),
            st.lists(kids, max_size=3).map(tuple)),
        max_leaves=8),
    max_size=4)


@settings(max_examples=25, deadline=None)
@given(_ckpt_tree)
def test_checkpoint_roundtrip_exact(tree):
    """save_checkpoint -> load_checkpoint(like=) is the identity: structure
    (incl. list-vs-tuple kinds and EMPTY subtrees via the %empty sentinel),
    dtypes (incl. the uint16-viewed bfloat16 path) and every bit of every
    leaf survive the flat-npz round trip (DESIGN.md §13)."""
    import os
    import tempfile

    import jax

    from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint

    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ck")
        save_checkpoint(path, tree, metadata={"prop": True})
        back = load_checkpoint(path, like=tree)

    la, sa = jax.tree_util.tree_flatten(tree)
    lb, sb = jax.tree_util.tree_flatten(back)
    assert sa == sb                      # container kinds + empties preserved
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype and x.shape == y.shape
        np.testing.assert_array_equal(x, y)


# ------------------------------------------------- Byzantine breakdown point
@st.composite
def corrupted_panel(draw):
    """(honest (h, p), corrupted (f, p), f) with f < (h + f) / 2: a
    minority of rows carrying ARBITRARY corruptions — huge finite values
    ([1e3, 1e6], either sign) or +/-inf."""
    h = draw(st.integers(3, 8))
    f = draw(st.integers(1, min(h - 1, 3)))          # f < m/2 guaranteed
    p = draw(st.integers(1, 6))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.default_rng(seed)
    honest = rng.normal(size=(h, p)).astype(np.float32)
    kind = draw(st.sampled_from(["inf", "big", "mixed"]))
    mag = rng.uniform(1e3, 1e6, size=(f, p)).astype(np.float32)
    sgn = np.where(rng.random((f, p)) < 0.5, -1, 1).astype(np.float32)
    bad = sgn * mag
    if kind == "inf":
        bad = sgn * np.float32(np.inf)
    elif kind == "mixed":
        bad = np.where(rng.random((f, p)) < 0.3, sgn * np.float32(np.inf),
                       bad)
    return honest, bad, f, seed


@settings(max_examples=30, deadline=None)
@given(corrupted_panel())
def test_robust_aggregators_respect_breakdown_point(panel):
    """With f < m/2 arbitrarily corrupted rows (+/-inf included), the
    median / trimmed-mean / Krum outputs stay inside the honest rows'
    per-coordinate convex hull (+eps) — the Byzantine breakdown-point
    property.  Plain fedavg demonstrably FAILS the same property: one
    unbounded row drags the weighted mean out of the hull."""
    import jax.numpy as jnp
    from repro.fed.aggregator_device import (
        coordinate_median, fedavg_combine, krum_combine,
        trimmed_mean_combine,
    )
    honest, bad, f, seed = panel
    rng = np.random.default_rng(seed + 1)
    x = np.concatenate([honest, bad], axis=0)
    perm = rng.permutation(x.shape[0])        # corruption order-independent
    x = x[perm]
    m = x.shape[0]
    xj, valid = jnp.asarray(x), jnp.ones(m, bool)
    lo = honest.min(0) - 1e-4
    hi = honest.max(0) + 1e-4
    med, _ = coordinate_median(xj, valid)
    # trim exactly enough for the one-sided worst case: k >= f needs
    # beta*m >= f, and beta < 0.5 keeps a non-empty window
    beta = min((f + 0.5) / m, 0.49)
    tm, _ = trimmed_mean_combine(xj, valid, jnp.float32(beta))
    km, chosen, _ = krum_combine(xj, valid, f, max(1, m - 2 * f - 2))
    for name, got in (("median", med), ("trimmed_mean", tm), ("krum", km)):
        got = np.asarray(got)
        assert np.isfinite(got).all(), name
        assert (got >= lo).all() and (got <= hi).all(), \
            f"{name} left the honest hull"
    # Krum never averages a corrupted row in
    bad_rows = np.flatnonzero(perm >= honest.shape[0])
    assert not np.asarray(chosen)[bad_rows].any()
    # fedavg fails: the unbounded rows drag the mean out of the hull
    fa = np.asarray(fedavg_combine(xj, jnp.ones(m, jnp.float32)))
    assert not ((fa >= lo).all() and (fa <= hi).all())

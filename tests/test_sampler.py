"""FedGS sampling optimizer (Eq. 16-17) + the paper's baseline samplers."""
import itertools

import numpy as np
import pytest

from repro.core.sampler import (
    FedGSSampler, MDSampler, PowerOfChoiceSampler, UniformSampler,
    _fedgs_solve, make_sampler,
)

import jax.numpy as jnp


def _brute_force(q, avail, m):
    """Exhaustive optimum of s^T Q s over |s|=m, s <= avail."""
    idx = np.flatnonzero(avail)
    best, best_val = None, -np.inf
    for combo in itertools.combinations(idx, m):
        s = np.zeros(len(avail))
        s[list(combo)] = 1
        val = s @ q @ s
        if val > best_val:
            best, best_val = set(combo), val
    return best, best_val


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_solver_near_bruteforce_optimum(seed):
    rng = np.random.default_rng(seed)
    n, m = 10, 3
    h = rng.random((n, n)) * 4
    h = 0.5 * (h + h.T)
    np.fill_diagonal(h, 0)
    z = rng.normal(size=n)
    q = h / n - np.diag(z)
    avail = rng.random(n) < 0.8
    avail[0] = True
    m_eff = min(m, int(avail.sum()))
    s = np.asarray(_fedgs_solve(jnp.asarray(q, jnp.float32), jnp.asarray(avail),
                                m=m_eff, max_sweeps=64))
    got = set(np.flatnonzero(s))
    sval = float(np.asarray(list(map(float, [0])))[0])  # placeholder
    sv = np.zeros(n); sv[list(got)] = 1
    got_val = sv @ q @ sv
    _, best_val = _brute_force(q, avail, m_eff)
    # greedy+swap local search must reach >= 95% of the exhaustive optimum
    # (and usually hits it exactly)
    assert got_val >= best_val - 0.05 * abs(best_val)


def test_solver_respects_constraints(rng):
    n, m = 20, 5
    q = rng.random((n, n)).astype(np.float32)
    q = 0.5 * (q + q.T)
    avail = rng.random(n) < 0.5
    avail[:2] = True
    m_eff = min(m, int(avail.sum()))
    s = np.asarray(_fedgs_solve(jnp.asarray(q), jnp.asarray(avail),
                                m=m_eff, max_sweeps=16))
    sel = np.flatnonzero(s)
    assert len(sel) == m_eff
    assert np.all(avail[sel])


def test_fedgs_alpha0_balances_counts(rng):
    """alpha=0: pure count-variance minimization -> picks least-sampled."""
    n, m = 8, 2
    sampler = FedGSSampler(alpha=0.0)
    sampler.set_graph(np.ones((n, n)) - np.eye(n))
    counts = np.array([5, 5, 5, 5, 0, 0, 5, 5], float)
    avail = np.ones(n, bool)
    sel = sampler.sample(avail=avail, m=m, rng=rng, counts=counts)
    assert set(sel) == {4, 5}


def test_fedgs_alpha_large_prefers_dispersion(rng):
    """alpha >> 0 with equal counts: picks the far-apart pair on the graph."""
    n = 4
    h = np.array([[0, 9, 1, 1], [9, 0, 1, 1], [1, 1, 0, 1], [1, 1, 1, 0.0]])
    sampler = FedGSSampler(alpha=50.0)
    sampler.set_graph(h)
    sel = sampler.sample(avail=np.ones(n, bool), m=2, rng=rng,
                         counts=np.zeros(n))
    assert set(sel) == {0, 1}


def test_fedgs_only_available(rng):
    n = 10
    sampler = FedGSSampler(alpha=1.0)
    sampler.set_graph(np.ones((n, n)) - np.eye(n))
    avail = np.zeros(n, bool)
    avail[[2, 7]] = True
    sel = sampler.sample(avail=avail, m=5, rng=rng, counts=np.zeros(n))
    assert set(sel) <= {2, 7} and len(sel) == 2


def test_uniform_sampler_properties(rng):
    s = UniformSampler()
    avail = np.zeros(30, bool)
    avail[5:20] = True
    sel = s.sample(avail=avail, m=6, rng=rng)
    assert len(sel) == 6 and len(set(sel)) == 6
    assert np.all(avail[sel])


def test_md_sampler_weights_by_size(rng):
    s = MDSampler()
    sizes = np.ones(50)
    sizes[:5] = 1000.0
    hits = np.zeros(50)
    for _ in range(200):
        sel = s.sample(avail=np.ones(50, bool), m=3, rng=rng, data_sizes=sizes)
        hits[sel] += 1
    assert hits[:5].sum() > hits[5:].sum()


def test_power_of_choice_picks_high_loss(rng):
    s = PowerOfChoiceSampler(d_factor=10)
    losses = np.arange(20, dtype=float)
    sel = s.sample(avail=np.ones(20, bool), m=3, rng=rng,
                   data_sizes=np.ones(20), losses=losses)
    assert set(sel) <= set(range(20))
    assert np.mean(losses[sel]) > np.mean(losses)


def test_make_sampler_factory():
    assert isinstance(make_sampler("uniform"), UniformSampler)
    assert isinstance(make_sampler("md"), MDSampler)
    assert isinstance(make_sampler("poc"), PowerOfChoiceSampler)
    assert isinstance(make_sampler("fedgs", alpha=2.0), FedGSSampler)
    with pytest.raises(ValueError):
        make_sampler("nope")


def test_md_sampler_degenerate_sizes_fall_back_to_uniform(rng):
    """All-zero data sizes used to NaN out w / w.sum(); now a uniform draw."""
    s = MDSampler()
    sel = s.sample(avail=np.ones(12, bool), m=4, rng=rng,
                   data_sizes=np.zeros(12))
    assert len(sel) == 4 and len(set(sel)) == 4
    # partial degeneracy: fewer nonzero-size availables than m also falls
    # back (rng.choice cannot fill m slots from a zero-mass support)
    sizes = np.zeros(12)
    sizes[0] = 5.0
    sel = s.sample(avail=np.ones(12, bool), m=4, rng=rng, data_sizes=sizes)
    assert len(sel) == 4


def test_poc_sampler_degenerate_sizes_fall_back_to_uniform(rng):
    s = PowerOfChoiceSampler(d_factor=2)
    losses = np.arange(12, dtype=float)
    sel = s.sample(avail=np.ones(12, bool), m=3, rng=rng,
                   data_sizes=np.zeros(12), losses=losses)
    assert len(sel) == 3
    # selection rule still applies on the uniform candidate set
    assert np.mean(losses[sel]) >= np.mean(losses) - 6


def test_host_samplers_empty_availability_return_empty(rng):
    """Regression (ISSUE 4 satellite): an all-False A_t used to reach
    ``rng.choice`` on an empty support and raise; every host sampler now
    returns an empty int array (the scan-path twins are covered in
    tests/test_sampler_device.py)."""
    n = 9
    avail = np.zeros(n, bool)
    sizes = np.ones(n)
    for s in (UniformSampler(), MDSampler(), PowerOfChoiceSampler()):
        sel = s.sample(avail=avail, m=3, rng=rng, data_sizes=sizes,
                       losses=np.arange(n, dtype=float))
        assert sel.size == 0 and sel.dtype.kind == "i", s.name
    g = FedGSSampler(alpha=1.0, max_sweeps=4)
    g.set_graph(np.ones((n, n)) - np.eye(n))
    sel = g.sample(avail=avail, m=3, rng=rng, counts=np.zeros(n))
    assert sel.size == 0


def test_md_select_degenerate_sizes_device():
    """Device-side MD: the log-floor makes all-zero sizes EQUAL weights
    (uniform Gumbel top-k), never NaN; zero-size clients still fill the
    mask when needed."""
    import jax
    from repro.core.sampler import md_select
    avail = jnp.ones(10, bool)
    s = np.asarray(md_select(jax.random.PRNGKey(0),
                             jnp.zeros(10), avail, 4))
    assert s.sum() == 4
    # mixed: the single positive-size client is effectively always taken,
    # zero-size clients complete the quota
    sizes = jnp.zeros(10).at[7].set(100.0)
    hits = np.zeros(10)
    for i in range(50):
        s = np.asarray(md_select(jax.random.PRNGKey(i), sizes, avail, 3))
        assert s.sum() == 3
        hits += s
    assert hits[7] == 50

"""3DG construction (paper §3.2): similarity -> adjacency -> shortest paths."""
import numpy as np
import pytest

from repro.core import graph as G


def test_normalize_01_bounds(rng):
    v = rng.normal(size=(20, 20))
    n = G.normalize_01(v)
    assert n.min() == 0.0 and n.max() == 1.0


def test_normalize_01_constant():
    assert np.all(G.normalize_01(np.full((4, 4), 3.0)) == 0.0)


def test_adjacency_semantics(rng):
    v = G.normalize_01(rng.random((10, 10)))
    r = G.similarity_to_adjacency(v, eps=0.3, sigma2=0.01)
    assert np.all(np.diag(r) == 0.0)
    off = ~np.eye(10, dtype=bool)
    edged = np.isfinite(r) & off
    # edges exist exactly where similarity >= eps
    assert np.array_equal(edged, (v >= 0.3) & off)
    # higher similarity => shorter edge
    i = np.unravel_index(np.argmax(np.where(edged, v, -1)), v.shape)
    j = np.unravel_index(np.argmin(np.where(edged, v, 2)), v.shape)
    assert r[i] <= r[j]


def test_floyd_warshall_matches_bruteforce(rng):
    n = 12
    r = rng.random((n, n)) * 5
    r = 0.5 * (r + r.T)
    r[rng.random((n, n)) < 0.5] = np.inf
    r = np.minimum(r, r.T)
    np.fill_diagonal(r, 0.0)
    h = G.shortest_paths(r)
    # brute force: O(n) rounds of min-plus until fixpoint
    want = r.copy()
    for _ in range(n):
        want = np.minimum(want, np.min(want[:, :, None] + want[None, :, :], axis=1))
    assert np.allclose(h, want, equal_nan=True, atol=1e-5)


def test_shortest_paths_triangle_inequality(rng):
    r = rng.random((16, 16)) * 3
    np.fill_diagonal(r, 0)
    h = G.shortest_paths(r)
    for k in range(16):
        # 1e-5 slack: the shared pipeline runs in float32 (DESIGN.md §9)
        assert np.all(h <= h[:, k:k + 1] + h[k:k + 1, :] + 1e-5)


def test_finite_cap():
    h = np.array([[0.0, 1.0, np.inf], [1.0, 0.0, 2.0], [np.inf, 2.0, 0.0]])
    c = G.finite_cap(h, scale=2.0)
    assert np.isfinite(c).all()
    assert c[0, 2] == 4.0          # 2 x max finite (=2)
    assert np.all(np.diag(c) == 0)


def test_oracle_vs_sspp_similarity(rng):
    """SSPP-constructed V equals the oracle dot-product V up to float error."""
    from repro.core.sspp import secure_similarity_matrix
    feats = rng.normal(size=(6, 8))
    v_oracle = feats @ feats.T
    v_sspp = secure_similarity_matrix(feats, seed=3)
    assert np.allclose(v_oracle, v_sspp, atol=1e-6)


def test_edge_f1_perfect_and_disjoint():
    r1 = np.array([[0, 1.0, np.inf], [1.0, 0, 1.0], [np.inf, 1.0, 0]])
    p, rec, f1 = G.edge_f1(r1, r1)
    assert f1 == pytest.approx(1.0)
    r2 = np.where(np.isfinite(r1), np.inf, 1.0)
    np.fill_diagonal(r2, 0)
    _, _, f1d = G.edge_f1(r2, r1)
    assert f1d == pytest.approx(0.0)


def test_functional_similarity_ranks_similar_clients(rng):
    """Clients with identical label dists should be more functionally similar
    than clients with disjoint ones (Eq. 12 sanity)."""
    e = np.stack([[1, 0, 0], [1, 0.1, 0], [0, 0, 1.0]])
    v = G.functional_similarity(e)
    assert v[0, 1] > v[0, 2]


def test_build_3dg_shapes(rng):
    feats = rng.random((9, 5))
    v, r, h = G.build_3dg(feats, eps=0.1, sigma2=0.01)
    assert v.shape == r.shape == h.shape == (9, 9)
    assert np.all(np.diag(h) == 0)
    # H is the min-plus closure: re-running FW changes nothing
    assert np.allclose(G.shortest_paths(h), h, equal_nan=True)
